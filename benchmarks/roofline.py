import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline analysis per (arch x shape) on the single-pod production mesh.

Methodology (documented in EXPERIMENTS.md §Roofline):

* XLA's cost_analysis counts loop bodies ONCE (verified: scan/while FLOPs are
  trip-count-blind), so whole-step numbers are useless for roofline.  Instead
  we lower unrolled COMPONENT variants of each model on the production mesh:

    v1 = 1 pattern-superblock, layers unrolled, naive attention (no inner
         loops -> every FLOP visible), production shardings
    v2 = 2 superblocks, same

  per-superblock = v2 - v1; whole model = v1 + (n_repeats-1 + tail/pattern) x
  per-superblock; train multiplies by the accumulation trip count.  Naive and
  deployed blocked attention execute the same matmul FLOPs (both compute all
  (q,kv) blocks and mask), so the FLOP count reflects the deployed baseline —
  including remat recompute, which is visible in the unrolled HLO.

* collective term uses the same component extrapolation with the DEPLOYED
  attention impl, summing collective-op result bytes from the per-device HLO.

* memory (HBM traffic) term is a documented analytic model (HLO 'bytes
  accessed' is also loop-blind): per-chip param reads/writes + activation
  traffic + KV-cache traffic; see `analytic_bytes`.

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import SHAPES, get_config, list_archs, skip_reason, train_accum  # noqa: E402
from repro.launch.mesh import HW, make_production_mesh  # noqa: E402
from repro.launch.dryrun import collective_stats  # noqa: E402

CHIPS = 256  # single-pod roofline


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS (the 6·N·D yardstick)
# ---------------------------------------------------------------------------


def model_flops(cfg, shape) -> float:
    """6·N_active·tokens (train) / 2·N_active·tokens (inference) + attention.

    Attention term per token per attn layer: 4·S_eff·H·Dh MACs->FLOPs
    (QK^T + PV), x3 for training (fwd + bwd). S_eff: causal S/2, window W,
    decode = cache length.
    """
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        base = 6.0 * n_active * tokens
        attn_mult = 3.0
        s_eff_full = shape.seq_len / 2
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        base = 2.0 * n_active * tokens
        attn_mult = 1.0
        s_eff_full = shape.seq_len / 2
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        base = 2.0 * n_active * tokens
        attn_mult = 1.0
        s_eff_full = shape.seq_len

    attn = 0.0
    for spec in cfg.layer_specs():
        if spec.kind != "attn":
            continue
        s_eff = min(cfg.sliding_window, s_eff_full) if spec.attn_type == "local" else s_eff_full
        attn += attn_mult * 4.0 * s_eff * cfg.n_heads * cfg.head_dim * tokens
    return base + attn


# ---------------------------------------------------------------------------
# analytic HBM-traffic model
# ---------------------------------------------------------------------------


def analytic_bytes(cfg, shape, accum: int) -> float:
    """Per-chip HBM bytes per step (documented model, not HLO-derived).

    train:  accum x (2 reads + 1 grad write of the device's param shard)
            + optimizer update (read p,m,v + write p,m,v)
            + activations: tokens/chip x d x L x ~20B (bf16 io + remat reread)
            + logits 3x toks/chip x V/tp x 2B
    prefill: 1 param read + activations 8B/coefficient + kv write
    decode:  param read (MoE: only routed experts) + full cache read + write
    """
    p_bytes = cfg.param_count()["total"] * jnp.dtype(cfg.param_dtype).itemsize
    p_shard = p_bytes / CHIPS
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab_size
    tp = 16
    if shape.kind == "train":
        toks_chip = shape.global_batch * shape.seq_len / CHIPS
        act = toks_chip * d * L * 20.0  # step total across all microbatches
        logits = 3.0 * toks_chip * (V / tp) * 2.0
        opt = 6.0 * p_shard  # read p,m,v + write p,m,v
        return accum * 3.0 * p_shard + act + logits + opt
    if shape.kind == "prefill":
        toks_chip = shape.global_batch * shape.seq_len / CHIPS
        return p_shard + toks_chip * d * L * 8.0
    # decode
    cache_bytes = _cache_bytes(cfg, shape) / CHIPS
    expert_frac = 1.0
    if cfg.moe is not None:
        expert_frac = min(1.0, shape.global_batch * cfg.moe.top_k / cfg.moe.n_experts)
        dense_frac = 1.0 - _moe_param_frac(cfg)
        expert_frac = dense_frac + _moe_param_frac(cfg) * expert_frac
    return p_shard * expert_frac + cache_bytes


def _moe_param_frac(cfg) -> float:
    pc = cfg.param_count()
    if cfg.moe is None:
        return 0.0
    inactive_plus_active = pc["total"] - (pc["total"] - cfg.active_param_count())
    expert_total = (pc["total"] - cfg.active_param_count()) / max(
        1 - cfg.moe.top_k / cfg.moe.n_experts, 1e-9
    )
    return min(expert_total / pc["total"], 1.0)


def _cache_bytes(cfg, shape) -> float:
    B, S = shape.global_batch, shape.seq_len
    total = 0.0
    for spec in cfg.layer_specs():
        if spec.kind == "attn":
            s_vis = S  # baseline caches full length even for local layers
            total += 2 * B * s_vis * cfg.n_kv_heads * cfg.head_dim * 2
        elif spec.kind == "mamba":
            m = cfg.mamba
            total += B * m.d_inner * (m.d_state * 4 + (m.d_conv - 1) * 2)
        elif spec.kind == "rwkv":
            r = cfg.rwkv
            total += B * (cfg.d_model // r.head_dim) * r.head_dim**2 * 4
    return total


# ---------------------------------------------------------------------------
# component HLO lowering
# ---------------------------------------------------------------------------


def _variant(cfg, k: int):
    """k-superblock unrolled variant of the arch config."""
    pat = len(cfg.block_pattern)
    return dataclasses.replace(cfg, name=f"{cfg.name}-v{k}", n_layers=pat * k)


def _lower_component(cfg, shape, mesh, attn_impl: str, kind: str):
    """Lower one unrolled variant; return (flops, coll_bytes) per device."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.dist.sharding import cache_specs, param_specs
    from repro.models import transformer

    from repro.launch.specs import FSDP_THRESHOLD

    params_shape = jax.eval_shape(lambda key: transformer.init_params(cfg, key), jax.random.PRNGKey(0))
    # match the deployed sharding policy: FSDP only above the threshold
    fsdp = get_config(_base_arch(cfg)).param_count()["total"] > FSDP_THRESHOLD
    pshard = jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_specs(params_shape, mesh, fsdp=fsdp)
    )
    act = {
        "h": NamedSharding(mesh, P("data", None, None)),
        "logits": NamedSharding(mesh, P("data", None, "model")),
    }

    if kind == "train":
        accum = train_accum(_base_arch(cfg))
        micro_bs = max(shape.global_batch // 16 // accum, 1) * 16  # global micro rows

        def fn(p, x, y):
            logits, mets = transformer.forward(p, x, cfg, attn_impl=attn_impl, shardings=act, unroll=True)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            ll = jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
            return -ll.sum() + mets["moe_aux"]

        gf = jax.grad(fn)
        toks = jax.ShapeDtypeStruct((micro_bs, shape.seq_len), jnp.int32)
        tsh = NamedSharding(mesh, P("data", None))
        lowered = jax.jit(gf, in_shardings=(pshard, tsh, tsh), out_shardings=pshard).lower(
            params_shape, toks, toks
        )
    elif kind == "prefill":
        B = shape.global_batch
        if cfg.embeds_input:
            toks = jax.ShapeDtypeStruct((B, shape.seq_len, cfg.d_model), jnp.bfloat16)
            tsh = NamedSharding(mesh, P("data", None, None))
        else:
            toks = jax.ShapeDtypeStruct((B, shape.seq_len), jnp.int32)
            tsh = NamedSharding(mesh, P("data", None))

        def fn(p, x):
            logits, _ = transformer.forward(p, x, cfg, attn_impl=attn_impl, shardings=act, unroll=True)
            return logits[:, -1]

        lowered = jax.jit(
            fn, in_shardings=(pshard, tsh), out_shardings=NamedSharding(mesh, P("data", "model"))
        ).lower(params_shape, toks)
    else:  # decode
        from repro.models import transformer as T

        B = shape.global_batch
        cache_shape = jax.eval_shape(lambda: T.init_cache(cfg, B, shape.seq_len))
        cshard = jax.tree.map(
            lambda s: NamedSharding(mesh, s), cache_specs(cache_shape, mesh, dp_axes=("data",))
        )
        b_ax = "data" if B % 16 == 0 else None
        if cfg.embeds_input:
            toks = jax.ShapeDtypeStruct((B, cfg.d_model), jnp.bfloat16)
            tsh = NamedSharding(mesh, P(b_ax, None))
        else:
            toks = jax.ShapeDtypeStruct((B,), jnp.int32)
            tsh = NamedSharding(mesh, P(b_ax))
        dact = {"h": NamedSharding(mesh, P(b_ax, None, None)), "logits": NamedSharding(mesh, P(b_ax, "model"))}

        def fn(p, c, t):
            return T.decode_step(p, c, t, cfg, shardings=dact, unroll=True)

        lowered = jax.jit(
            fn,
            in_shardings=(pshard, cshard, tsh),
            out_shardings=(NamedSharding(mesh, P(b_ax, "model")), cshard),
            donate_argnums=(1,),
        ).lower(params_shape, cache_shape, toks)

    compiled = lowered.compile()
    flops = float(compiled.cost_analysis().get("flops", 0.0))
    colls = collective_stats(compiled.as_text())
    coll_bytes = sum(s["bytes"] for s in colls.values())
    return flops, coll_bytes


def _base_arch(cfg) -> str:
    return cfg.name.split("-v")[0]


def _deploy_collectives(arch: str, shape_name: str, mesh) -> float:
    """Per-step per-device collective bytes from the DEPLOY lowering (the same
    step the dry-run compiles), with loop bodies weighted by trip counts:
    trips = [accumulation W, layer-scan repeats] for train, [repeats] for
    serving."""
    from repro.launch.dryrun import loop_aware_collective_bytes
    from repro.launch.specs import plan_cell

    plan = plan_cell(arch, shape_name, mesh)
    donate = (0,) if plan.kind == "train" else ((1,) if plan.kind == "decode" else ())
    compiled = (
        jax.jit(
            plan.fn,
            in_shardings=plan.in_shardings,
            out_shardings=plan.out_shardings,
            donate_argnums=donate,
        )
        .lower(*plan.abstract_args)
        .compile()
    )
    cfg = plan.cfg
    if plan.kind == "train":
        trips = [plan.scfg.w_max, cfg.n_repeats]
    else:
        trips = [cfg.n_repeats]
    stats = loop_aware_collective_bytes(compiled.as_text(), trips)
    return float(stats["weighted_bytes"])


def roofline_cell(arch: str, shape_name: str, mesh, attn_impl_deploy: str = "blocked") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name}
    reason = skip_reason(arch, shape_name)
    if reason:
        rec.update(status="skipped", reason=reason)
        return rec
    t0 = time.time()
    accum = train_accum(arch) if shape.kind == "train" else 1

    # FLOPs: unrolled naive-attention component variants (exact, extrapolated)
    f1, _ = _lower_component(_variant(cfg, 1), shape, mesh, "naive", shape.kind)
    f2, _ = _lower_component(_variant(cfg, 2), shape, mesh, "naive", shape.kind)

    pat = len(cfg.block_pattern)
    eff_repeats = cfg.n_layers / pat  # includes the tail as fractional repeats
    per_sb_f, base_f = f2 - f1, f1 - (f2 - f1)
    flops_dev = (base_f + eff_repeats * per_sb_f) * accum
    if shape.kind == "train":
        # component lowering used micro_bs rows; scale to the global batch
        micro_rows = max(shape.global_batch // 16 // accum, 1) * 16
        flops_dev *= shape.global_batch / (micro_rows * accum)

    # collectives: loop-aware measurement of the full deployed step
    coll_dev = _deploy_collectives(arch, shape_name, mesh)

    bytes_dev = analytic_bytes(cfg, shape, accum)

    t_compute = flops_dev / HW.PEAK_FLOPS_BF16
    t_memory = bytes_dev / HW.HBM_BW
    t_coll = coll_dev / HW.ICI_BW
    dominant = max(
        [("compute", t_compute), ("memory", t_memory), ("collective", t_coll)], key=lambda x: x[1]
    )[0]
    mf = model_flops(cfg, shape)
    hlo_total = flops_dev * CHIPS
    rec.update(
        status="ok",
        kind=shape.kind,
        accum=accum,
        flops_per_dev=flops_dev,
        coll_bytes_per_dev=coll_dev,
        hbm_bytes_per_dev=float(bytes_dev),
        t_compute_s=t_compute,
        t_memory_s=t_memory,
        t_collective_s=t_coll,
        bound=dominant,
        model_flops=mf,
        useful_flops_ratio=mf / max(hlo_total, 1.0),
        roofline_frac=t_compute / max(t_compute, t_memory, t_coll),
        analysis_s=round(time.time() - t0, 1),
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--attn-impl", default="blocked")
    ap.add_argument("--out", default="results/roofline.json")
    args = ap.parse_args()

    mesh = make_production_mesh()
    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    records = []
    for arch in archs:
        for shape_name in shapes:
            try:
                rec = roofline_cell(arch, shape_name, mesh, args.attn_impl)
            except Exception as e:  # noqa: BLE001
                rec = {"arch": arch, "shape": shape_name, "status": "error", "error": f"{type(e).__name__}: {e}"}
            records.append(rec)
            if rec["status"] == "ok":
                print(
                    f"{arch:28s} {shape_name:12s} compute {rec['t_compute_s']*1e3:9.3f}ms "
                    f"mem {rec['t_memory_s']*1e3:9.3f}ms coll {rec['t_collective_s']*1e3:9.3f}ms "
                    f"-> {rec['bound']:10s} useful {rec['useful_flops_ratio']:.2f} "
                    f"roofline {rec['roofline_frac']:.2f}",
                    flush=True,
                )
            else:
                print(f"{arch:28s} {shape_name:12s} {rec['status']}: {rec.get('reason', rec.get('error'))}", flush=True)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(records, f, indent=1)
    print(f"-> {args.out}")


if __name__ == "__main__":
    main()
