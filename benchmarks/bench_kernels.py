"""Kernel micro-benchmarks (interpret-mode wall time is NOT TPU perf — the
derived column reports the analytic FLOPs/bytes each call would execute on
TPU, which is what the BlockSpec tiling targets)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, iters=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def bench_flash_attention():
    from repro.kernels.ops import flash_attention

    B, S, H, Hkv, Dh = 1, 256, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, Dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh), jnp.float32)
    us = _time(lambda *a: flash_attention(*a), q, k, v, iters=2)
    flops = 4 * B * H * S * (S / 2) * Dh
    return [("kernel_flash_attention_256", us, f"tpu_flops={flops:.3g}")]


def bench_rwkv6_scan():
    from repro.kernels.ops import rwkv6_scan

    B, T, H, D = 1, 128, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, D)) for i in range(3))
    w = 0.5 + 0.49 * jax.random.uniform(ks[3], (B, T, H, D))
    u = jax.random.normal(ks[4], (H, D)) * 0.1
    us = _time(lambda *a: rwkv6_scan(*a), r, k, v, w, u, iters=2)
    chunk = 32
    flops = B * H * (T / chunk) * (2 * chunk * D * D * 3 + 2 * chunk * chunk * D * 2)
    return [("kernel_rwkv6_scan_128", us, f"tpu_flops={flops:.3g}")]


def bench_weighted_accum():
    from repro.kernels.ops import weighted_accum

    n = 1 << 20
    a = jax.random.normal(jax.random.PRNGKey(0), (n,))
    g = jax.random.normal(jax.random.PRNGKey(1), (n,))
    us = _time(lambda *x: weighted_accum(*x, 0.5), a, g, iters=2)
    return [("kernel_weighted_accum_1M", us, f"hbm_bytes={3*4*n} (fused: 1r+1r+1w)")]


ALL = [bench_flash_attention, bench_rwkv6_scan, bench_weighted_accum]
