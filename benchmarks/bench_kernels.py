"""Kernel micro-benchmarks (interpret-mode wall time is NOT TPU perf — the
derived column reports the analytic FLOPs/bytes each call would execute on
TPU, which is what the BlockSpec tiling targets)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, iters=3):
    """Best-of-N microsecond timing.  Best-of (not mean-of): scheduler noise
    and lazy-allocation warm-up only ever ADD time, so the minimum is the
    cleanest estimate of the call's true cost on a shared CPU runner."""
    jax.block_until_ready(fn(*args))  # compile/warm
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def bench_flash_attention():
    from repro.kernels.ops import flash_attention

    B, S, H, Hkv, Dh = 1, 256, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, Dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh), jnp.float32)
    us = _time(lambda *a: flash_attention(*a), q, k, v, iters=2)
    flops = 4 * B * H * S * (S / 2) * Dh
    return [("kernel_flash_attention_256", us, f"tpu_flops={flops:.3g}")]


def bench_paged_attention():
    from repro.kernels.ops import paged_attention

    B, H, Hkv, Dh = 4, 4, 2, 64
    n_pages, ps, p_max = 32, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, Dh), jnp.float32)
    k_pool = jax.random.normal(ks[1], (n_pages + 1, ps, Hkv, Dh), jnp.float32)
    v_pool = jax.random.normal(ks[2], (n_pages + 1, ps, Hkv, Dh), jnp.float32)
    # ragged live lengths: 100 / 37 / 8 / 0 tokens
    lengths = jnp.array([100, 37, 8, 0], jnp.int32)
    table = -jnp.ones((B, p_max), jnp.int32)
    page = 0
    for b, ln in enumerate([100, 37, 8, 0]):
        for j in range(-(-ln // ps)):
            table = table.at[b, j].set(page)
            page += 1
    us = _time(lambda *a: paged_attention(*a), q, k_pool, v_pool, table, lengths, iters=2)
    live_pages = sum(-(-ln // ps) for ln in [100, 37, 8, 0])
    flops = 4 * H * Dh * live_pages * ps  # only live pages do work (pl.when skip)
    dense_flops = 4 * H * Dh * B * p_max * ps
    return [(
        "kernel_paged_attention_rag", us,
        f"tpu_flops={flops:.3g} (dense_equiv={dense_flops:.3g}, {dense_flops / flops:.2f}x)",
    )]


def bench_rwkv6_scan():
    from repro.kernels.ops import rwkv6_scan

    B, T, H, D = 1, 128, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, D)) for i in range(3))
    w = 0.5 + 0.49 * jax.random.uniform(ks[3], (B, T, H, D))
    u = jax.random.normal(ks[4], (H, D)) * 0.1
    us = _time(lambda *a: rwkv6_scan(*a), r, k, v, w, u, iters=2)
    chunk = 32
    flops = B * H * (T / chunk) * (2 * chunk * D * D * 3 + 2 * chunk * chunk * D * 2)
    return [("kernel_rwkv6_scan_128", us, f"tpu_flops={flops:.3g}")]


def bench_weighted_accum():
    from repro.kernels.ops import weighted_accum

    n = 1 << 20
    a = jax.random.normal(jax.random.PRNGKey(0), (n,))
    g = jax.random.normal(jax.random.PRNGKey(1), (n,))
    us = _time(lambda *x: weighted_accum(*x, 0.5), a, g, iters=2)
    return [("kernel_weighted_accum_1M", us, f"hbm_bytes={3*4*n} (fused: 1r+1r+1w)")]


ALL = [bench_flash_attention, bench_paged_attention, bench_rwkv6_scan, bench_weighted_accum]
