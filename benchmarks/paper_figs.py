"""Benchmarks reproducing each paper table/figure (CSV rows out).

Figure map:
  fig6  -> bench_convergence      static ratios don't change convergence
  fig7  -> bench_ratio_speed_1m   epoch time vs ratio, 1 machine (1080ti+2080ti)
  fig8  -> bench_ratio_speed_2m   epoch time vs ratio, 2 machines (V100+2080ti)
  fig9  -> bench_adaptive_2w      adaptive trajectory, 2 workers
  fig10 -> bench_adaptive_3w      adaptive trajectory, 3 workers
  fig11 -> bench_hetero_cluster   add / replace a worker
  fig12 -> bench_adpsgd_2w        2-worker AD-PSGD degenerates; allocation wins
  fig13 -> bench_speedup          speedups vs PS / AllReduce with 2x & 5x stragglers
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    AdaptiveAllocationController,
    ClusterSpec,
    CommModel,
    ControllerConfig,
    WorkerSpeed,
    simulate_adpsgd,
    simulate_ps,
    simulate_sync,
    speedup,
)
from repro.data import SyntheticImages
from repro.models.convnet import convnet_forward, init_convnet, xent_loss
from repro.optim import SGDConfig, sgd_init, sgd_update

Row = tuple  # (name, value, derived)


# ---------------------------------------------------------------------------
# fig 6 — convergence is ratio-independent (real training, paper's ConvNet)
# ---------------------------------------------------------------------------


def bench_convergence() -> list[Row]:
    """Train ConvNet on synthetic MNIST under 4 allocations of the SAME global
    batch; final losses must coincide (paper fig. 6)."""
    rows = []
    data = SyntheticImages(shape=(28, 28, 1), n_samples=512, seed=0)
    ratios = {"5:5": (5, 5), "6:4": (6, 4), "3:7": (3, 7), "7:3": (7, 3)}
    finals = {}
    for name, ratio in ratios.items():
        key = jax.random.PRNGKey(0)  # same init for every ratio
        params = init_convnet(key, width=8)
        opt = sgd_init(params)
        scfg = SGDConfig(momentum=0.9, weight_decay=1e-4)
        C, mb = sum(ratio), 10
        steps = 30
        t0 = time.perf_counter()
        for step in range(steps):
            idx = np.arange(step * C * mb, (step + 1) * C * mb) % len(data)
            batch = data.batch(idx)
            # allocation changes WHO computes, not WHAT: grads averaged over
            # the same C*mb samples -> identical update (paper eq. 1)
            x = jnp.asarray(batch["images"])
            y = jnp.asarray(batch["labels"])
            g = jax.grad(lambda p: xent_loss(convnet_forward(p, x), y))(params)
            params, opt = sgd_update(g, opt, params, 0.01, scfg)
        loss = float(xent_loss(convnet_forward(params, x), y))
        us = (time.perf_counter() - t0) / steps * 1e6
        finals[name] = loss
        rows.append((f"fig6_convergence_ratio_{name}", us, f"final_loss={loss:.4f}"))
    spread = max(finals.values()) - min(finals.values())
    rows.append(("fig6_convergence_spread", 0.0, f"max_final_loss_spread={spread:.5f}"))
    return rows


# ---------------------------------------------------------------------------
# figs 7/8 — epoch time vs static ratio
# ---------------------------------------------------------------------------


def _ratio_speed(cluster: ClusterSpec, groups, total, tag) -> list[Row]:
    comm = CommModel(grad_bytes=50e6)
    rows = []
    best = None
    for name, ratio in groups.items():
        t0 = time.perf_counter()
        log = simulate_sync(
            cluster, epochs=3, total_micro=total, comm=comm, policy="static",
            static_ratios=ratio, jitter=False,
        )
        us = (time.perf_counter() - t0) * 1e6 / 3
        epoch_s = float(log.makespans.mean())
        rows.append((f"{tag}_ratio_{name}", us, f"epoch_s={epoch_s:.4f}"))
        if best is None or epoch_s < best[1]:
            best = (name, epoch_s)
    rows.append((f"{tag}_best", 0.0, f"best_ratio={best[0]}"))
    return rows


def bench_ratio_speed_1m() -> list[Row]:
    """fig 7: one machine, GTX1080ti + RTX2080ti, ratios 5:5 6:4 3:7 7:3."""
    cluster = ClusterSpec.from_gpus(["rtx2080ti", "gtx1080ti"], jitter=0.0)
    groups = {"5:5": (5, 5), "6:4": (6, 4), "3:7": (3, 7), "7:3": (7, 3)}
    return _ratio_speed(cluster, groups, 10, "fig7")


def bench_ratio_speed_2m() -> list[Row]:
    """fig 8: two machines, V100 + RTX2080ti, ratios 10:10 12:8 2:18 15:5."""
    cluster = ClusterSpec.from_gpus(["v100", "rtx2080ti"], jitter=0.0)
    groups = {"10:10": (10, 10), "12:8": (12, 8), "2:18": (2, 18), "15:5": (15, 5)}
    return _ratio_speed(cluster, groups, 20, "fig8")


# ---------------------------------------------------------------------------
# figs 9/10 — adaptive trajectory
# ---------------------------------------------------------------------------


def _adaptive(cluster, total, tag, epochs=10) -> list[Row]:
    t0 = time.perf_counter()
    log = simulate_sync(cluster, epochs=epochs, total_micro=total, policy="adaptive")
    us = (time.perf_counter() - t0) * 1e6 / epochs
    m = log.makespans
    allocs = log.allocations
    stable_epoch = next(
        (e for e in range(1, epochs) if np.all(np.abs(np.diff(allocs[e - 1 : e + 1], axis=0)) <= 1)),
        epochs,
    )
    gain = 1.0 - m[-1] / m[0]
    return [
        (f"{tag}_epoch0_s", us, f"makespan={m[0]:.4f}"),
        (f"{tag}_final_s", us, f"makespan={m[-1]:.4f}"),
        (f"{tag}_gain", 0.0, f"epoch_time_reduction={gain:.3f}"),
        (f"{tag}_stable_epoch", 0.0, f"ratio_stable_at_epoch={stable_epoch}"),
        (f"{tag}_final_alloc", 0.0, "w=" + ":".join(map(str, allocs[-1]))),
    ]


def bench_adaptive_2w() -> list[Row]:
    """fig 9: V100 + RTX2080ti, two initial ratios converge to the same point."""
    rows = []
    cluster = ClusterSpec.from_gpus(["v100", "rtx2080ti"], jitter=0.02)
    rows += _adaptive(cluster, 20, "fig9_init_equal")
    ctl = AdaptiveAllocationController(
        ControllerConfig(total=20, n_workers=2), initial_allocation=[5, 15]
    )
    log = simulate_sync(cluster, 10, 20, policy="adaptive", controller=ctl)
    rows.append(
        ("fig9_init_skewed_final_alloc", 0.0, "w=" + ":".join(map(str, log.allocations[-1])))
    )
    return rows


def bench_adaptive_3w() -> list[Row]:
    """fig 10: V100 + 2x RTX2080ti."""
    cluster = ClusterSpec.from_gpus(["v100", "rtx2080ti", "rtx2080ti"], jitter=0.02)
    return _adaptive(cluster, 30, "fig10")


# ---------------------------------------------------------------------------
# fig 11 — add / replace a worker
# ---------------------------------------------------------------------------


def bench_hetero_cluster() -> list[Row]:
    comm = CommModel(grad_bytes=50e6)
    base = ClusterSpec.from_gpus(["v100", "rtx2080ti"], jitter=0.0)
    plus = base.with_added(WorkerSpeed(name="rtx2080ti:2", throughput=14.5))
    two2080 = ClusterSpec.from_gpus(["rtx2080ti", "rtx2080ti"], jitter=0.0)
    rows = []
    for tag, cluster in [("v100+2080ti", base), ("v100+2x2080ti", plus), ("2x2080ti", two2080)]:
        log = simulate_sync(cluster, epochs=10, total_micro=24, comm=comm, policy="adaptive")
        rows.append((f"fig11_{tag}", 0.0, f"steady_epoch_s={log.makespans[-1]:.4f}"))
    return rows


# ---------------------------------------------------------------------------
# figs 12/13 — cross-system comparison
# ---------------------------------------------------------------------------


def bench_adpsgd_2w() -> list[Row]:
    """fig 12: with 2 workers AD-PSGD's pairwise averaging couples both
    workers; the allocation algorithm still exploits the speed gap."""
    cluster = ClusterSpec.from_gpus(["rtx2080ti", "gtx1080ti"], jitter=0.0)
    comm = CommModel(grad_bytes=50e6)
    C, epochs = 20, 10
    target = C * epochs
    ad = simulate_adpsgd(cluster, target_samples=target, comm=comm)
    adapt = simulate_sync(cluster, epochs, C, comm, policy="adaptive").total_time()
    equal = simulate_sync(cluster, epochs, C, comm, policy="equal").total_time()
    return [
        ("fig12_adpsgd_s", 0.0, f"wall={ad['wall_clock_s']:.3f}"),
        ("fig12_allreduce_equal_s", 0.0, f"wall={equal:.3f}"),
        ("fig12_allocation_s", 0.0, f"wall={adapt:.3f}"),
        ("fig12_allocation_vs_adpsgd", 0.0, f"speedup={speedup(ad['wall_clock_s'], adapt):.2f}x"),
    ]


def bench_speedup() -> list[Row]:
    """fig 13: speedup of the allocation algorithm vs PS and equal AllReduce
    with a 2x and a 5x straggler (paper: ~5.36x vs PS @2x, 2.75x @5x)."""
    rows = []
    comm = CommModel(grad_bytes=100e6)
    C, epochs = 40, 12
    for factor, tag in [(2.0, "2x"), (5.0, "5x")]:
        workers = [WorkerSpeed(f"w{i}", 10.0) for i in range(3)] + [
            WorkerSpeed("straggler", 10.0 / factor)
        ]
        cluster = ClusterSpec(workers=workers)
        adapt = simulate_sync(cluster, epochs, C, comm, policy="adaptive").total_time()
        equal = simulate_sync(cluster, epochs, C, comm, policy="equal").total_time()
        ps = simulate_ps(cluster, epochs, C, comm).total_time()
        rows.append((f"fig13_vs_ps_{tag}", 0.0, f"speedup={speedup(ps, adapt):.2f}x"))
        rows.append((f"fig13_vs_allreduce_{tag}", 0.0, f"speedup={speedup(equal, adapt):.2f}x"))
    return rows


ALL = [
    bench_convergence,
    bench_ratio_speed_1m,
    bench_ratio_speed_2m,
    bench_adaptive_2w,
    bench_adaptive_3w,
    bench_hetero_cluster,
    bench_adpsgd_2w,
    bench_speedup,
]
